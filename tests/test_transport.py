"""Cluster gradient transport: codec, buckets, sync rounds, ring wire.

Everything here runs IN-PROCESS (threads stand in for worker processes) so
the suite stays fast; the real multi-process path is covered by
``benchmarks/cluster_smoke.py`` and the elastic test in
``tests/test_cluster.py``.  The property under test throughout is the
transport's determinism invariant: the reduced value is the f32 sum, in
process-id order, of the decoded per-worker payloads — so replicas that
start identical stay BIT-identical, with or without compression.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.topology import ClusterSpec, TransportSpec
from repro.launch.cluster import SyncClient, SyncServer
from repro.launch.transport import (
    GradCodec, GradReducer, RingTransport, StarTransport, SyncPeerLost,
    build_wire_transport,
)


# ---------------------------------------------------------------------------
# TransportSpec
# ---------------------------------------------------------------------------


def test_transport_spec_validates():
    assert TransportSpec().compression == "none"
    with pytest.raises(ValueError, match="compression"):
        TransportSpec(compression="zstd")
    with pytest.raises(ValueError, match="topology"):
        TransportSpec(topology="mesh")
    with pytest.raises(ValueError, match="topk_ratio"):
        TransportSpec(compression="topk", topk_ratio=0.0)
    with pytest.raises(ValueError, match="buckets"):
        TransportSpec(buckets=0)


def test_transport_spec_production_preset_and_dict_coercion():
    p = TransportSpec.production()
    assert (p.compression, p.topology, p.overlap) == ("int8", "ring", True)
    assert p.buckets > 1
    q = TransportSpec.production(topology="star", timeout=7.0)
    assert q.topology == "star" and q.timeout == 7.0
    # ClusterSpec accepts the kwargs-dict form (the CLI/JSON path)
    cs = ClusterSpec(processes=2, transport={"compression": "int8"})
    assert isinstance(cs.transport, TransportSpec)
    assert cs.transport.compression == "int8"


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------


def test_codec_raw_roundtrip():
    codec = GradCodec(TransportSpec())
    vec = np.arange(10, dtype=np.float32)
    payload = codec.encode(0, vec)
    np.testing.assert_array_equal(codec.decode(payload), vec)
    assert GradCodec.nbytes(payload) == vec.nbytes


def test_codec_int8_bounded_error_and_compression():
    rng = np.random.default_rng(0)
    vec = rng.standard_normal(4096).astype(np.float32)
    codec = GradCodec(TransportSpec(compression="int8", chunk=512))
    payload = codec.encode(0, vec)
    dec = codec.decode(payload)
    # per-chunk scale = absmax/127 -> error bounded by half a quantum
    scale = np.repeat(payload["s"], 512)[: vec.size]
    assert np.all(np.abs(dec - vec) <= scale * 0.5 + 1e-7)
    assert GradCodec.nbytes(payload) < vec.nbytes / 3.5


def test_codec_topk_keeps_largest_and_is_deterministic():
    vec = np.array([0.1, -5.0, 0.2, 3.0, -0.3, 0.05, 2.0, -1.0],
                   dtype=np.float32)
    codec = GradCodec(TransportSpec(compression="topk", topk_ratio=0.25))
    payload = codec.encode(0, vec)
    assert payload["k"] == "topk"
    assert list(payload["i"]) == [1, 3]          # |-5| and |3|, index-sorted
    dec = codec.decode(payload)
    np.testing.assert_array_equal(dec[[1, 3]], vec[[1, 3]])
    assert dec[[0, 2, 4, 5, 6, 7]].sum() == 0.0
    # same input re-encoded by a fresh codec -> byte-identical payload
    p2 = GradCodec(
        TransportSpec(compression="topk", topk_ratio=0.25)
    ).encode(0, vec)
    assert p2["i"].tobytes() == payload["i"].tobytes()
    assert p2["v"].tobytes() == payload["v"].tobytes()


def test_codec_error_feedback_reinjects_quantization_error():
    """Sending the SAME vector repeatedly, the running mean of the decoded
    payloads converges on the true vector: the residual re-enters each
    step instead of accumulating as bias."""
    rng = np.random.default_rng(1)
    vec = rng.standard_normal(2048).astype(np.float32) * 1e-3
    codec = GradCodec(TransportSpec(compression="topk", topk_ratio=0.05))
    total = np.zeros_like(vec)
    n = 40
    for _ in range(n):
        total += codec.decode(codec.encode(0, vec))
    err0 = np.linalg.norm(codec.decode(codec.encode(1, vec)) - vec)
    err_mean = np.linalg.norm(total / n - vec)
    assert err_mean < err0 / 4          # the mean is far closer than 1 shot


def test_codec_residual_resets_on_shape_change():
    codec = GradCodec(TransportSpec(compression="int8"))
    codec.encode(0, np.ones(100, dtype=np.float32))
    assert codec._residual[0].shape == (100,)
    codec.encode(0, np.ones(50, dtype=np.float32))   # elastic replan
    assert codec._residual[0].shape == (50,)


# ---------------------------------------------------------------------------
# Bucket planning
# ---------------------------------------------------------------------------


def test_plan_buckets_contiguous_and_balanced():
    from repro.train.steps import plan_buckets

    leaves = {
        "a": np.zeros((100,), np.float32),
        "b": np.zeros((100,), np.float32),
        "c": np.zeros((100,), np.float32),
        "d": np.zeros((100,), np.float32),
    }
    groups = plan_buckets(leaves, 2)
    assert groups == ((0, 1), (2, 3))
    # every leaf exactly once, in order
    flat = [i for g in groups for i in g]
    assert flat == list(range(4))
    # more buckets than leaves clamps; zero clamps to 1
    assert len(plan_buckets(leaves, 99)) == 4
    assert plan_buckets(leaves, 0) == (tuple(range(4)),)


def test_plan_buckets_byte_weighted():
    from repro.train.steps import plan_buckets

    leaves = [
        np.zeros((1000,), np.float32),   # one huge leaf ...
        np.zeros((10,), np.float32),
        np.zeros((10,), np.float32),
        np.zeros((10,), np.float32),
    ]
    groups = plan_buckets(leaves, 2)
    assert groups == ((0,), (1, 2, 3))   # ... gets a bucket of its own


# ---------------------------------------------------------------------------
# SyncServer rounds (the satellite fixes)
# ---------------------------------------------------------------------------


def _spawn_clients(server, n, timeout=10.0):
    return [
        SyncClient(server.address, pid, timeout=timeout) for pid in range(n)
    ]


def test_sync_allgather_is_pid_ordered():
    server = SyncServer(3)
    try:
        clients = _spawn_clients(server, 3)
        out = [None] * 3

        def go(pid):
            out[pid] = clients[pid].allgather("g", f"blob-{pid}")

        ts = [threading.Thread(target=go, args=(p,)) for p in range(3)]
        [t.start() for t in ts]
        [t.join(10) for t in ts]
        assert out[0] == out[1] == out[2] == ["blob-0", "blob-1", "blob-2"]
    finally:
        server.close()


def test_sync_tag_reuse_across_steps():
    """Rounds retire once every participant has read the result, so the
    same tag is reusable next step (the reducer reuses ``step/N/bK``
    layouts and long runs must not leak round state)."""
    server = SyncServer(2)
    try:
        clients = _spawn_clients(server, 2)
        for step in range(3):
            out = [None, None]

            def go(pid):
                out[pid] = clients[pid].allreduce("grad", {"v": pid + step})

            ts = [threading.Thread(target=go, args=(p,)) for p in (0, 1)]
            [t.start() for t in ts]
            [t.join(10) for t in ts]
            assert out[0] == out[1] == {"v": 2 * step + 1}
        assert not server._rounds        # nothing left behind
    finally:
        server.close()


def test_sync_round_poisoned_after_peer_death():
    """A participant dying mid-round must NOT hang the survivors: once the
    coordinator marks it dead, the blocked join raises ``SyncPeerLost``."""
    server = SyncServer(2)
    try:
        (client,) = _spawn_clients(server, 2)[:1]
        err = []

        def go():
            try:
                client.allreduce("g", {"v": 1.0})
            except SyncPeerLost as e:
                err.append(e)

        t = threading.Thread(target=go)
        t.start()
        time.sleep(0.2)                  # let the join block on peer 1
        server.mark_dead(1)
        t.join(10)
        assert err and "lost" in str(err[0])
    finally:
        server.close()


def test_sync_concurrent_large_payloads():
    """Back-to-back rounds with MB-scale arrays: the tree-sum runs outside
    the server lock, so concurrent joins on other tags make progress and
    every client sees the correct pid-ordered result."""
    server = SyncServer(4)
    try:
        clients = _spawn_clients(server, 4)
        big = np.full(1 << 18, 1.0, dtype=np.float32)   # 1 MiB each
        out = [None] * 4

        def go(pid):
            acc = []
            for r in range(2):
                acc.append(
                    clients[pid].allreduce(f"big/{r}", big * (pid + 1))
                )
            out[pid] = acc

        ts = [threading.Thread(target=go, args=(p,)) for p in range(4)]
        [t.start() for t in ts]
        [t.join(30) for t in ts]
        for pid in range(4):
            for r in range(2):
                np.testing.assert_array_equal(out[pid][r], big * 10.0)
    finally:
        server.close()


def test_sync_kv_retires_on_read():
    server = SyncServer(1)
    try:
        (client,) = _spawn_clients(server, 1)
        assert client.get("addr") is None
        client.put("addr", [1, 2])
        assert client.get("addr") == [1, 2]
        assert client.get("addr") is None          # consumed exactly once
    finally:
        server.close()


def test_sync_client_timeout_on_silent_coordinator():
    """A coordinator that accepts the handshake then goes mute must raise
    ``SyncPeerLost`` after the configured timeout, not block forever."""
    from multiprocessing import connection

    listener = connection.Listener(
        ("127.0.0.1", 0), authkey=b"repro-cluster-sync"
    )
    stop = threading.Event()

    def mute_server():
        conn = listener.accept()
        conn.recv()                          # hello
        conn.send({"ok": True, "n": 2})
        stop.wait(10)                        # then say nothing, ever
        conn.close()

    t = threading.Thread(target=mute_server, daemon=True)
    t.start()
    host, port = listener.address
    client = SyncClient(f"{host}:{port}", 0, timeout=0.3)
    with pytest.raises(SyncPeerLost, match="silent"):
        client.barrier("never")
    stop.set()
    listener.close()


# ---------------------------------------------------------------------------
# Ring wire (threads as workers)
# ---------------------------------------------------------------------------


def _ring_workers(n, fn, timeout=15.0):
    """Run ``fn(pid, ring)`` on n threads, each owning a RingTransport."""
    server = SyncServer(n)
    results, errs = [None] * n, []

    def worker(pid):
        sync = SyncClient(server.address, pid, timeout=timeout)
        ring = None
        try:
            ring = RingTransport(sync, pid, n, timeout=timeout)
            results[pid] = fn(pid, ring)
        except BaseException as e:       # pragma: no cover - diagnostics
            errs.append((pid, e))
        finally:
            if ring is not None:
                ring.close()
            sync.close()

    ts = [threading.Thread(target=worker, args=(p,)) for p in range(n)]
    [t.start() for t in ts]
    [t.join(60) for t in ts]
    server.close()
    assert not errs, errs
    return results


def test_ring_allgather_three_workers():
    def fn(pid, ring):
        out = []
        for r in range(3):                    # several rounds, same ring
            out.append(ring.allgather(f"r{r}", {"pid": pid, "r": r}))
        return out

    results = _ring_workers(3, fn)
    for rnd in range(3):
        expect = [{"pid": p, "r": rnd} for p in range(3)]
        assert all(res[rnd] == expect for res in results)


def test_ring_large_blobs_do_not_deadlock():
    """Blobs far beyond the socket buffer: the background sender thread is
    what keeps n simultaneous forwards from deadlocking the ring."""
    big = np.arange(1 << 19, dtype=np.float32)          # 2 MiB

    def fn(pid, ring):
        got = ring.allgather("big", big * pid)
        return [float(g.sum()) for g in got]

    results = _ring_workers(3, fn, timeout=30.0)
    expect = [float((big * p).sum()) for p in range(3)]
    assert results[0] == results[1] == results[2] == expect


def test_build_wire_transport_selects_topology():
    assert build_wire_transport(TransportSpec(), None, 0, 4) is None
    assert build_wire_transport(TransportSpec(), object(), 0, 1) is None
    star = build_wire_transport(TransportSpec(), object(), 0, 2)
    assert isinstance(star, StarTransport)


# ---------------------------------------------------------------------------
# GradReducer end-to-end (virtual replicas)
# ---------------------------------------------------------------------------


def _reduce_workers(n, spec, fn, timeout=20.0):
    """n worker threads, each with its own SyncClient + wire + reducer."""
    server = SyncServer(n)
    results, errs = [None] * n, []

    def worker(pid):
        sync = SyncClient(server.address, pid, timeout=timeout)
        red = None
        try:
            wire = build_wire_transport(spec, sync, pid, n)
            red = GradReducer(wire, spec, pid, n)
            results[pid] = fn(pid, red)
        except BaseException as e:       # pragma: no cover - diagnostics
            errs.append((pid, e))
        finally:
            if red is not None:
                red.close()
            sync.close()

    ts = [threading.Thread(target=worker, args=(p,)) for p in range(n)]
    [t.start() for t in ts]
    [t.join(90) for t in ts]
    server.close()
    assert not errs, errs
    return results


@pytest.mark.parametrize("spec", [
    TransportSpec(),                                           # star tree-sum
    TransportSpec(compression="int8", buckets=2, overlap=True),
    TransportSpec(compression="int8", topology="ring", buckets=2,
                  overlap=True),
    TransportSpec(compression="topk", topk_ratio=0.1, topology="ring"),
], ids=["star-none", "star-int8-overlap", "ring-int8-overlap", "ring-topk"])
def test_reducer_replicas_bit_identical(spec):
    """Every topology x compression combo: all replicas receive byte-equal
    reduced vectors and tree-summed extras, across steps."""
    rng = np.random.default_rng(7)
    grads = {
        pid: [rng.standard_normal(700).astype(np.float32) for _ in range(4)]
        for pid in range(3)
    }

    def fn(pid, red):
        out = []
        for step in range(4):
            vecs, sums = red.reduce(
                f"step/{step}",
                [grads[pid][step][:512], grads[pid][step][512:]],
                {"loss": float(pid + step)},
            )
            out.append((
                b"".join(np.asarray(v).tobytes() for v in vecs),
                sums["loss"],
            ))
        return out

    results = _reduce_workers(3, spec, fn)
    assert results[0] == results[1] == results[2]
    # the extras really are the cross-replica sum
    assert results[0][0][1] == pytest.approx(0 + 1 + 2)


def test_reducer_error_feedback_convergence():
    """Compressed training tracks uncompressed: 2 virtual replicas descend
    a quadratic with int8-reduced gradients; replicas stay bit-identical
    every step and the final loss lands within tolerance of the exact
    run's."""
    target = np.linspace(-2.0, 2.0, 600).astype(np.float32)

    def descend(spec):
        steps = 60

        def fn(pid, red):
            x = np.zeros_like(target)      # identical start on all replicas
            history = []
            rng = np.random.default_rng(100 + pid)
            for step in range(steps):
                noise = rng.standard_normal(x.size).astype(np.float32) * 0.05
                grad = (x - target) / 2 + noise   # per-replica half-grad
                (g,), _ = red.reduce(f"s/{step}", [grad], None)
                x = x - 0.1 * np.asarray(g)
                history.append(x.tobytes())
            return float(np.mean((x - target) ** 2)), history

        res = _reduce_workers(2, spec, fn)
        assert res[0][1] == res[1][1]      # bit-identical EVERY step
        return res[0][0]

    exact = descend(TransportSpec())
    int8 = descend(TransportSpec(compression="int8", buckets=1))
    assert exact < 0.02                    # the exact run converges
    assert abs(int8 - exact) < 0.01       # compressed tracks it


def test_reducer_reports_wire_stats():
    spec = TransportSpec(compression="int8")

    def fn(pid, red):
        for step in range(3):
            red.reduce(f"s/{step}", [np.ones(2048, np.float32)], None)
        return red.stats.snapshot()

    stats = _reduce_workers(2, spec, fn)[0]
    assert stats["steps"] == 3
    assert stats["compression_ratio"] > 3.0
    assert stats["wire_bytes_per_step"] < stats["raw_bytes_per_step"]
