"""Ring/hierarchical/compressed allreduce + pipeline + sharding-rule tests.

These spawn a subprocess with XLA_FLAGS=8 fake devices, because the main test
process must keep the default 1-device CPU (jax locks device count at init).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    _divisible_spec, make_rules, spec_for, specs_for_tree,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_ring_allreduce_equals_psum():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.compat import make_mesh
        from repro.distributed.allreduce import ring_allreduce
        mesh = make_mesh((8,), ('data',))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1037))
        ring = shard_map(lambda v: ring_allreduce(v[0], 'data')[None], mesh=mesh,
                         in_specs=P('data'), out_specs=P('data'), check_rep=False)
        ref = shard_map(lambda v: jax.lax.psum(v[0], 'data')[None], mesh=mesh,
                        in_specs=P('data'), out_specs=P('data'), check_rep=False)
        err = float(jnp.max(jnp.abs(ring(x) - ref(x))))
        print('ERR', err)
        assert err < 1e-4, err
    """)
    assert "ERR" in out


def test_hierarchical_allreduce_equals_sum():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.compat import make_mesh
        from repro.distributed.allreduce import hierarchical_allreduce
        mesh = make_mesh((2, 4), ('pod', 'data'))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 515))
        f = shard_map(
            lambda v: hierarchical_allreduce(v[0, 0], intra_axis='data',
                                             inter_axis='pod')[None, None],
            mesh=mesh, in_specs=P('pod', 'data'), out_specs=P('pod', 'data'),
            check_rep=False)
        err = float(jnp.max(jnp.abs(f(x)[0, 0] - x.sum(axis=(0, 1)))))
        print('ERR', err)
        assert err < 1e-4, err
    """)
    assert "ERR" in out


def test_compressed_allreduce_error_feedback():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.compat import make_mesh
        from repro.distributed.allreduce import compressed_allreduce
        mesh = make_mesh((4,), ('data',))
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (4, 4096))
        noise = jax.random.uniform(jax.random.fold_in(key, 1), (4, 4096))
        res = jnp.zeros((4, 4096))
        f = shard_map(
            lambda v, r, n: tuple(t[None] for t in compressed_allreduce(
                v[0], r[0], n[0], axis='data', rows=64)),
            mesh=mesh, in_specs=(P('data'),) * 3,
            out_specs=(P('data'), P('data')), check_rep=False)
        total, new_res = f(x, res, noise)
        exact = x.sum(0)
        # quantized sum within 4 * max scale of exact; residual = local error
        err = float(jnp.max(jnp.abs(total[0] - exact)))
        scale_bound = 4 * float(jnp.max(jnp.abs(x))) / 127 * 2
        print('ERR', err, scale_bound)
        assert err < scale_bound, (err, scale_bound)
        # error feedback invariant: x + old_res == dequant + new_res
        assert float(jnp.max(jnp.abs(new_res))) <= float(jnp.max(jnp.abs(x))) / 127 * 1.01
    """)
    assert "ERR" in out


def test_pipeline_matches_sequential():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.distributed.pipeline import pipeline_apply
        mesh = make_mesh((4,), ('stage',))
        key = jax.random.PRNGKey(0)
        S, M, mb, d = 4, 8, 2, 16
        Ws = jax.random.normal(key, (S, d, d)) * 0.3
        x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))
        out = pipeline_apply(lambda p, h: jnp.tanh(h @ p['w']), {'w': Ws}, x,
                             mesh=mesh)
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ Ws[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        print('ERR', err)
        assert err < 1e-5
    """, n=4)
    assert "ERR" in out


# ---------------------------------------------------------------------------
# sharding rules (pure, no devices needed)
# ---------------------------------------------------------------------------


def test_spec_for_basic():
    rules = make_rules()
    assert spec_for(("vocab", "embed"), rules) == P("model")
    assert spec_for(("batch", "seq"), rules) == P(("pod", "data"))
    assert spec_for(("layers", "embed", "mlp"), rules) == P(None, None, "model")


def test_spec_for_no_duplicate_axes():
    rules = make_rules(fsdp=True)
    # embed->data and batch->(pod,data) in one spec: data must appear once
    s = spec_for(("batch", "embed"), rules)
    flat = []
    for part in s:
        if isinstance(part, tuple):
            flat.extend(part)
        elif part is not None:
            flat.append(part)
    assert len(flat) == len(set(flat))


def test_divisible_spec_drops_uneven(monkeypatch):
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    s = _divisible_spec(P(None, "model", None), (4, 56, 128), FakeMesh())
    assert s == P(None, None)[0:0] or s == P()  # 56 % 16 != 0 -> dropped
    s2 = _divisible_spec(P(None, "model", None), (4, 64, 128), FakeMesh())
    assert s2 == P(None, "model")


def test_specs_for_tree():
    rules = make_rules()
    axes = {"a": ("vocab", "embed"), "b": {"c": ("mlp", "embed")}}
    specs = specs_for_tree(axes, rules)
    assert specs["a"] == P("model")
    assert specs["b"]["c"] == P("model")
