"""repro.serve: allocator lifecycle, scheduler invariants (property-based),
engine-vs-ServeSession greedy parity, and prefix-cache bit-identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ServeSession
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.serve import (
    BlockAllocator, EngineConfig, RequestMeta, SamplingParams, Scheduler,
    ServeEngine, hash_chain,
)

from tests._hypothesis_compat import given, settings, st

SMOKE_CONFIG = EngineConfig(
    max_slots=2, max_len=48, block_size=4, num_blocks=32,
    prefill_chunk=8, token_budget=16,
)


@pytest.fixture(scope="module")
def built():
    """One (model, params, session, engine) per arch, built lazily."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch)
            model = get_model(cfg)
            params, _ = model.init_params(key=jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_cycle():
    a = BlockAllocator(4, 8)
    bids = [a.allocate() for _ in range(4)]
    assert sorted(bids) == [0, 1, 2, 3]
    assert a.allocate() is None                 # exhausted: all referenced
    for b in bids:
        a.free(b)
    assert a.num_free == 4
    assert a.allocate() is not None             # anonymous blocks recycle


def test_allocator_refcount_and_lookup():
    a = BlockAllocator(4, 8)
    bid = a.allocate(h=123)
    assert a.refcount(bid) == 1
    hit = a.lookup(123)
    assert hit == bid and a.refcount(bid) == 2
    a.decref(bid)
    assert a.refcount(bid) == 1
    a.decref(bid)
    # at refcount 0 a hashed block is cached, not freed: still a hit target
    assert a.refcount(bid) == 0
    assert a.contains(123)
    assert a.lookup(123) == bid                 # resurrected
    assert a.refcount(bid) == 1


def test_allocator_lru_eviction():
    a = BlockAllocator(2, 8)
    b0 = a.allocate(h=10)
    b1 = a.allocate(h=11)
    a.decref(b0)
    a.decref(b1)                                # both cached; b0 is LRU
    b2 = a.allocate(h=12)                       # evicts b0
    assert b2 == b0
    assert not a.contains(10)
    assert a.contains(11) and a.contains(12)
    assert a.stats.evictions == 1


def test_allocator_referenced_blocks_never_evicted():
    a = BlockAllocator(2, 8)
    b0 = a.allocate(h=10)                       # stays referenced
    b1 = a.allocate(h=11)
    a.decref(b1)
    assert a.allocate(h=12) == b1               # only the cached one evictable
    assert a.allocate(h=13) is None             # everything referenced now
    assert a.contains(10)


def test_allocator_error_paths():
    a = BlockAllocator(2, 8)
    with pytest.raises(ValueError):
        a.decref(0)                             # not live
    bid = a.allocate(h=5)
    with pytest.raises(ValueError):
        a.allocate(h=5)                         # duplicate hash
    a.incref(bid)
    a.decref(bid)
    assert a.refcount(bid) == 1


def test_hash_chain_full_blocks_only():
    assert hash_chain([1, 2, 3], 4) == []
    c1 = hash_chain([1, 2, 3, 4], 4)
    c2 = hash_chain([1, 2, 3, 4, 5, 6, 7, 8], 4)
    assert len(c1) == 1 and len(c2) == 2
    assert c2[0] == c1[0]                       # chained: shared prefix, same hash
    assert c2[1] != c1[0]
    assert hash_chain([9, 2, 3, 4, 5, 6, 7, 8], 4)[1] != c2[1]  # prefix differs


# ---------------------------------------------------------------------------
# Scheduler invariants (property-based)
# ---------------------------------------------------------------------------


def _drive(max_slots, token_budget, prefill_chunk, reqs):
    """Run the scheduler to completion, checking invariants each step.
    Returns (finish_step_by_rid, steps_taken)."""
    sched = Scheduler(max_slots=max_slots, token_budget=token_budget,
                      prefill_chunk=prefill_chunk)
    for i, (plen, mnt) in enumerate(reqs):
        sched.add(RequestMeta(request_id=i, prompt_len=plen,
                              max_new_tokens=mnt))
    finish = {}
    admitted_order = []
    limit = 10_000
    for step in range(limit):
        if not sched.has_work():
            break
        admitted_order.extend(sched.admit())
        s = sched.schedule()

        # budget is a hard ceiling
        assert s.total_tokens <= token_budget
        # slot exclusivity: each slot owned by at most one unfinished request
        slots = [r.slot for r in sched.requests.values() if r.slot is not None]
        assert len(slots) == len(set(slots))
        assert all(0 <= sl < max_slots for sl in slots)

        for w in s.prefill:
            sched.note_prefilled(w)
        for rid in s.decode:
            sched.note_decoded(rid)
        for rid in list(s.decode) + [w.request_id for w in s.prefill if w.last]:
            if sched.is_done(rid) and rid not in finish:
                finish[rid] = step
                sched.finish(rid)
    else:
        raise AssertionError("scheduler did not drain (starvation)")

    # FCFS admission: slots are granted in submission order
    assert admitted_order == sorted(admitted_order)
    assert len(finish) == len(reqs)             # everyone finished
    return finish, step


def test_scheduler_basic_drain():
    finish, _ = _drive(2, 16, 8, [(10, 4), (3, 2), (20, 1)])
    assert set(finish) == {0, 1, 2}


def test_scheduler_decode_prioritized_over_prefill():
    sched = Scheduler(max_slots=2, token_budget=8, prefill_chunk=8)
    sched.add(RequestMeta(request_id=0, prompt_len=4, max_new_tokens=4))
    sched.admit()
    w = sched.schedule().prefill[0]
    sched.note_prefilled(w)                     # now RUNNING
    sched.add(RequestMeta(request_id=1, prompt_len=32, max_new_tokens=1))
    sched.admit()
    s = sched.schedule()
    assert s.decode == (0,)                     # decode always gets its token
    assert s.prefill and s.prefill[0].request_id == 1
    assert s.prefill[0].end - s.prefill[0].start == 7   # budget 8 - 1 decode


@settings(max_examples=60, deadline=None)
@given(
    max_slots=st.integers(1, 4),
    chunk=st.integers(1, 8),
    extra=st.integers(0, 8),
    reqs=st.lists(
        st.tuples(st.integers(1, 25), st.integers(1, 6)),
        min_size=1, max_size=12,
    ),
)
def test_scheduler_invariants_property(max_slots, chunk, extra, reqs):
    """No step exceeds the budget, admission is FCFS, slots are exclusive,
    and every request terminates — for arbitrary request mixes."""
    _drive(max_slots, chunk + extra, chunk, reqs)


# ---------------------------------------------------------------------------
# engine vs one-shot ServeSession (greedy parity)
# ---------------------------------------------------------------------------

PARITY_ARCHS = ["deepseek-7b", "qwen3-moe-30b-a3b", "rwkv6-7b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_engine_matches_one_shot_generate(arch, built):
    cfg, model, params = built(arch)
    session = ServeSession(model=model, params=params)
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (3, 11), 0, cfg.vocab)
    n_new = 5
    # one-shot oracle: (B, 1 + n_new) including the prefill-sampled token
    oracle = session.generate(prompts, max_new_tokens=n_new).tokens

    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    outs = engine.generate_batch(
        [prompts[i].tolist() for i in range(3)], max_new_tokens=n_new + 1
    )
    for i, out in enumerate(outs):
        assert out.tokens == np.asarray(oracle[i]).tolist()
        assert out.finish_reason == "length"


def test_engine_sampled_matches_session_sampled(built):
    """Same per-request key schedule => batched one-shot and engine rows
    draw identical sampled chains (request id == row index)."""
    cfg, model, params = built("deepseek-7b")
    session = ServeSession(model=model, params=params)
    sp = SamplingParams(temperature=0.9, top_k=16, seed=7)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab)
    oracle = session.generate(prompts, max_new_tokens=4, sampling=sp).tokens

    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    outs = engine.generate_batch(
        [prompts[i].tolist() for i in range(2)], max_new_tokens=5, sampling=sp
    )
    for i, out in enumerate(outs):
        assert out.tokens == np.asarray(oracle[i]).tolist()


def test_engine_eos_stops_early(built):
    cfg, model, params = built("deepseek-7b")
    session = ServeSession(model=model, params=params)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    ref = [int(t) for t in session.generate(prompt, max_new_tokens=7).tokens[0]]
    eos = ref[3]                                # force a stop mid-stream
    import dataclasses
    engine = ServeEngine(
        model=model, params=params,
        config=dataclasses.replace(SMOKE_CONFIG, eos_token=eos),
    )
    out = engine.generate_batch([prompt[0].tolist()], max_new_tokens=8)[0]
    assert out.finish_reason == "stop"
    assert out.tokens == ref[:4]                # up to and including eos


# ---------------------------------------------------------------------------
# prefix cache: a hit is bit-identical to a cold prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "rwkv6-7b"])
def test_prefix_cache_hit_is_bit_identical(arch, built):
    cfg, model, params = built(arch)
    prompt = jax.random.randint(
        jax.random.PRNGKey(4), (14,), 0, cfg.vocab
    ).tolist()
    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    cold = engine.generate_batch([prompt], max_new_tokens=6)[0]
    q_before = engine.prefix_cache_stats.hit_blocks
    warm = engine.generate_batch([prompt], max_new_tokens=6)[0]
    assert engine.prefix_cache_stats.hit_blocks > q_before   # actually reused
    assert warm.tokens == cold.tokens


def test_prefix_cache_shared_prefix_across_requests(built):
    cfg, model, params = built("deepseek-7b")
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab, size=12).tolist()
    a = prefix + rng.integers(0, cfg.vocab, size=4).tolist()
    b = prefix + rng.integers(0, cfg.vocab, size=4).tolist()

    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    shared = engine.generate_batch([a, b], max_new_tokens=4)
    assert engine.prefix_cache_stats.hit_blocks > 0

    solo = []
    for p in (a, b):
        e = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
        solo.append(e.generate_batch([p], max_new_tokens=4)[0])
    for got, want in zip(shared, solo):
        assert got.tokens == want.tokens


# ---------------------------------------------------------------------------
# int8 KV-cache serving
# ---------------------------------------------------------------------------


def _pool_bytes(adapter):
    return sum(l.nbytes for l in jax.tree_util.tree_leaves(adapter.pool))


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b"])
def test_engine_int8_kv_serving(arch, built):
    """kv_cache_dtype='int8': the paged pool stores int8 KV + per-row scales
    (~4x fewer pool bytes) and greedy decode stays token-identical to the
    native-dtype engine on the smoke models."""
    cfg, model, params = built(arch)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 10), 0, cfg.vocab)
    plist = [prompts[i].tolist() for i in range(2)]

    native = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    ref = native.generate_batch(plist, max_new_tokens=5)

    model8 = get_model(cfg.with_(kv_cache_dtype="int8"))
    engine8 = ServeEngine(model=model8, params=params, config=SMOKE_CONFIG)
    out8 = engine8.generate_batch(plist, max_new_tokens=5)

    # int8 KV + (1/D-sized) f32 scales vs native KV: well under half the bytes
    assert _pool_bytes(engine8.adapter) < 0.5 * _pool_bytes(native.adapter)
    for a, b in zip(out8, ref):
        assert a.tokens == b.tokens
        assert a.finish_reason == "length"


# ---------------------------------------------------------------------------
# engine behavior
# ---------------------------------------------------------------------------


def test_streaming_events_in_order_and_done_once(built):
    cfg, model, params = built("deepseek-7b")
    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    rids = [engine.submit([1 + i, 2, 3, 4, 5], max_new_tokens=4)
            for i in range(3)]
    seen = {r: [] for r in rids}
    dones = []
    while engine.has_work():
        for ev in engine.step():
            seen[ev.request_id].append(ev)
            if ev.done:
                dones.append(ev.request_id)
    for rid in rids:
        idxs = [e.index for e in seen[rid]]
        assert idxs == list(range(len(idxs)))   # per-request token order
        assert [e.done for e in seen[rid][:-1]] == [False] * (len(idxs) - 1)
        assert seen[rid][-1].done
        toks = [e.token for e in seen[rid]]
        assert toks == engine.output(rid).tokens
    assert sorted(dones) == sorted(rids)        # each finishes exactly once


def test_admit_mid_decode_continuous_batching(built):
    """A request submitted while another decodes gets tokens before the first
    finishes — the continuous-batching property."""
    cfg, model, params = built("deepseek-7b")
    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    r0 = engine.submit([5, 6, 7, 8], max_new_tokens=10)
    engine.step()                               # r0 prefilled, starts decoding
    r1 = engine.submit([9, 10, 11, 12], max_new_tokens=2)
    first_r1 = None
    r0_done_at = None
    step = 1
    while engine.has_work():
        for ev in engine.step():
            if ev.request_id == r1 and first_r1 is None:
                first_r1 = step
            if ev.request_id == r0 and ev.done:
                r0_done_at = step
        step += 1
    assert first_r1 is not None and r0_done_at is not None
    assert first_r1 < r0_done_at


def test_submit_validation(built):
    cfg, model, params = built("deepseek-7b")
    engine = ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
    with pytest.raises(ValueError):
        engine.submit([], max_new_tokens=4)
    with pytest.raises(ValueError):
        engine.submit([1, 2], max_new_tokens=0)
    with pytest.raises(ValueError):
        engine.submit(list(range(60)), max_new_tokens=4)  # exceeds max_len


def test_engine_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(prefill_chunk=6, block_size=4)


def test_unsupported_family_raises(built):
    cfg, model, params = built("whisper-medium")
    with pytest.raises(NotImplementedError):
        ServeEngine(model=model, params=params, config=SMOKE_CONFIG)
