"""Compiled-kernel validation (``@pytest.mark.tpu``): the same parity claims
test_kernels.py proves in interpret mode, re-run through the real Mosaic
lowering with ``interpret=False``.  Auto-skipped off-TPU (see conftest.py) —
these exist so a TPU CI lane certifies the int8-fused kernels end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

pytestmark = pytest.mark.tpu

KEY = jax.random.PRNGKey(11)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def test_flash_attention_q8_compiled_matches_oracle():
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (2, 256, 4, 64))
    k = _rand(ks[1], (2, 256, 2, 64))
    v = _rand(ks[2], (2, 256, 2, 64))
    out = ops.flash_attention_q8(q, k, v, causal=True, interpret=False)
    ref = R.flash_attention_q8_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_q8_compiled_grad_finite():
    ks = jax.random.split(jax.random.fold_in(KEY, 1), 3)
    q = _rand(ks[0], (1, 128, 2, 64))
    k = _rand(ks[1], (1, 128, 2, 64))
    v = _rand(ks[2], (1, 128, 2, 64))
    g = jax.grad(lambda t: ops.flash_attention_q8(
        *t, causal=True, interpret=False).sum())((q, k, v))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in g)


def test_rwkv6_scan_q8_compiled_matches_oracle():
    ks = jax.random.split(jax.random.fold_in(KEY, 2), 5)
    B, S, H, D = 2, 128, 2, 64
    r = _rand(ks[0], (B, S, H, D)) * 0.5
    k = _rand(ks[1], (B, S, H, D)) * 0.5
    v = _rand(ks[2], (B, S, H, D)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, D))))
    u = _rand(ks[4], (H, D)) * 0.5
    out, s_fin = ops.rwkv6_scan_q8(r, k, v, w, u, chunk=32, interpret=False)
    ref, s_ref = R.rwkv6_scan_q8_ref(r, k, v, w, u)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(s_fin, s_ref, atol=5e-5, rtol=5e-5)


def test_rglru_scan_q8_compiled_matches_oracle():
    ks = jax.random.split(jax.random.fold_in(KEY, 3), 2)
    B, S, W = 2, 128, 256
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.99
    x = _rand(ks[1], (B, S, W))
    out = ops.rglru_scan_q8(a, x, chunk=32, interpret=False)
    ref = R.rglru_scan_q8_ref(a, x)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_fused_moe_combine_compiled_bitexact():
    from repro.kernels import fused_moe as FM

    T, d, E, k, C = 128, 64, 8, 2, 8
    ks = jax.random.split(jax.random.fold_in(KEY, 4), 3)
    x = _rand(ks[0], (T, d))
    router = jax.random.normal(ks[1], (d, E)) * 0.5
    slot_tok, _gate, st, slot, keep, _aux = FM.moe_routing(x, router, k, C)
    y = _rand(ks[2], (E * C, d))
    got = FM.fused_moe_combine(y, slot_tok, T, interpret=False)
    want = FM._combine_xla(y, st, slot, keep, T, E, C)
    assert bool(jnp.all(got == want))
