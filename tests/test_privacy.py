"""Direct unit coverage for core/privacy.py's custody audit.

Previously audit_custody was only exercised indirectly through the fleet
tests; these pin down the edge cases — empty log, quarantine-before-provision
ordering, duplicate shard ids — and the two pathology counters.
"""
import pytest

from repro.core.privacy import CustodyEvent, audit_custody

CLEAN = {
    "private_shards_rehomed": 0,
    "private_shards_resurrected": 0,
    "duplicate_provisions": 0,
}


def ev(kind, shard_id, private, src=None, dst=None):
    return CustodyEvent(kind=kind, shard_id=shard_id, private=private,
                        src=src, dst=dst)


def test_empty_log_is_clean():
    assert audit_custody([]) == CLEAN


def test_normal_lifecycle_is_clean():
    log = [
        ev("provision", "priv-0", True, dst="w0"),
        ev("provision", "pub", False, dst="w0"),
        ev("rehome", "pub", False, src="w0", dst="w1"),
        ev("quarantine", "priv-0", True, src="w0"),
    ]
    assert audit_custody(log) == CLEAN


def test_private_rehome_is_counted():
    log = [
        ev("provision", "priv-0", True, dst="w0"),
        ev("rehome", "priv-0", True, src="w0", dst="w1"),
    ]
    assert audit_custody(log)["private_shards_rehomed"] == 1


def test_provision_after_quarantine_is_resurrection():
    log = [
        ev("provision", "priv-0", True, dst="w0"),
        ev("quarantine", "priv-0", True, src="w0"),
        ev("provision", "priv-0", True, dst="w2"),
    ]
    audit = audit_custody(log)
    assert audit["private_shards_resurrected"] == 1
    assert audit["private_shards_rehomed"] == 0


def test_quarantine_before_provision_ordering_matters():
    # quarantine FIRST: the later provision of the same private shard is a
    # resurrection even though the event multiset equals the normal lifecycle
    log = [
        ev("quarantine", "priv-0", True, src="w0"),
        ev("provision", "priv-0", True, dst="w0"),
    ]
    assert audit_custody(log)["private_shards_resurrected"] == 1
    assert audit_custody(list(reversed(log)))[
        "private_shards_resurrected"] == 0


def test_duplicate_provision_same_custodian_is_flagged():
    log = [
        ev("provision", "pub", False, dst="w0"),
        ev("provision", "pub", False, dst="w0"),
    ]
    assert audit_custody(log)["duplicate_provisions"] == 1


def test_same_shard_id_on_two_custodians_is_not_a_duplicate():
    # a public shard legitimately provisioned to two workers (split reads)
    log = [
        ev("provision", "pub", False, dst="w0"),
        ev("provision", "pub", False, dst="w1"),
    ]
    assert audit_custody(log) == CLEAN


def test_rehome_then_reprovision_to_old_custodian_is_clean():
    # the re-home moved custody away, so w0 taking the shard back later via
    # a fresh provision is a custody change, not a double-count
    log = [
        ev("provision", "pub", False, dst="w0"),
        ev("rehome", "pub", False, src="w0", dst="w1"),
        ev("provision", "pub", False, dst="w0"),
    ]
    assert audit_custody(log)["duplicate_provisions"] == 0


def test_public_resurrection_is_not_counted():
    # only PRIVATE shards have the tombstone invariant
    log = [
        ev("quarantine", "pub", False, src="w0"),
        ev("provision", "pub", False, dst="w1"),
    ]
    assert audit_custody(log)["private_shards_resurrected"] == 0


def test_unknown_event_kind_rejected():
    with pytest.raises(ValueError, match="unknown custody event kind"):
        CustodyEvent(kind="teleport", shard_id="x", private=False)
