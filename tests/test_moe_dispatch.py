"""Equivalence of the shard_map group-local EP dispatch vs the dense path.

Guards the §Perf headline optimization: the group-local dispatch
(models/moe.py::_moe_mlp_local) must match the GSPMD-auto dense reference
bit-near-exactly — forward AND gradients — on a real (data, model) mesh.
Runs in a subprocess with 4 fake devices (the main process stays 1-device).
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_local_dispatch_matches_dense_forward_and_grad():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs import smoke_config
        from repro.models import moe
        from repro.models.api import get_model
        from repro.optim import adamw
        from repro.train.steps import make_train_step

        # capacity_factor high enough that no token drops: paths must agree
        cfg = smoke_config('qwen3-moe-30b-a3b').with_(capacity_factor=8.0)
        m = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params, _ = m.init_params(key=key)
        batch = {
            'tokens': jax.random.randint(key, (4, 16), 0, cfg.vocab),
            'labels': jax.random.randint(key, (4, 16), 0, cfg.vocab),
            'loss_mask': jnp.ones((4, 16), jnp.float32),
        }
        opt = adamw()
        step = make_train_step(m, opt, lambda s: 1e-3)

        moe.MOE_IMPL = 'dense'
        ref, aux_ref = jax.jit(lambda p, t: m.forward(p, t))(params, batch['tokens'])
        _, _, m1 = jax.jit(step)(params, opt.init(params), batch)

        mesh = make_mesh((2, 2), ('data', 'model'))
        moe.MOE_IMPL = 'auto'
        with set_mesh(mesh):
            out, aux = jax.jit(lambda p, t: m.forward(p, t))(params, batch['tokens'])
            _, _, m2 = jax.jit(step)(params, opt.init(params), batch)

        ferr = float(jnp.max(jnp.abs(out - ref)))
        aerr = float(jnp.abs(aux - aux_ref))
        lerr = abs(float(m1['loss']) - float(m2['loss']))
        gerr = abs(float(m1['grad_norm']) - float(m2['grad_norm']))
        print('ERRS', ferr, aerr, lerr, gerr)
        assert ferr < 5e-4, ferr   # scatter-add ordering tolerance
        assert aerr < 1e-6, aerr
        assert lerr < 1e-5, lerr
        assert gerr < 1e-2, gerr
    """)
    assert "ERRS" in out


def test_local_dispatch_matches_dense_under_capacity_overflow():
    """Tokens ARE dropped: with capacity_factor=0.01 the per-expert capacity
    floors at 8 slots for 512 token-copies.  On a pure model-parallel mesh
    (n_groups == 1) the local path's per-group capacity equals the dense C,
    so which copies drop — and hence the output — must match exactly."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs import smoke_config
        from repro.models import moe
        from repro.models.api import get_model

        cfg = smoke_config('qwen3-moe-30b-a3b').with_(capacity_factor=0.01)
        assert moe.expert_capacity(256, cfg.n_experts, cfg.experts_per_token,
                                   cfg.capacity_factor) == 8
        m = get_model(cfg)
        key = jax.random.PRNGKey(3)
        params, _ = m.init_params(key=key)
        tokens = jax.random.randint(key, (4, 64), 0, cfg.vocab)

        moe.MOE_IMPL = 'dense'
        ref, aux_ref = jax.jit(lambda p, t: m.forward(p, t))(params, tokens)
        # sanity: drops really happen — uncapped output must differ
        big = smoke_config('qwen3-moe-30b-a3b').with_(capacity_factor=8.0)
        ref_big, _ = jax.jit(
            lambda p, t: get_model(big).forward(p, t))(params, tokens)
        assert float(jnp.max(jnp.abs(ref - ref_big))) > 1e-3

        mesh = make_mesh((1, 4), ('data', 'model'))
        moe.MOE_IMPL = 'auto'
        with set_mesh(mesh):
            out, aux = jax.jit(lambda p, t: m.forward(p, t))(params, tokens)
        ferr = float(jnp.max(jnp.abs(out - ref)))
        aerr = float(jnp.abs(aux - aux_ref))
        print('ERRS', ferr, aerr)
        assert ferr < 5e-4, ferr
        assert aerr < 1e-6, aerr
    """)
    assert "ERRS" in out


def test_local_dispatch_over_model_batch_layout():
    """The DP-attention layout (batch sharded over model too): the explicit
    all-gather + psum_scatter path must also match."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.compat import make_mesh, set_mesh
        from repro.configs import smoke_config
        from repro.distributed.sharding import make_rules, set_rules
        from repro.models import moe
        from repro.models.api import get_model

        cfg = smoke_config('qwen3-moe-30b-a3b').with_(capacity_factor=8.0)
        m = get_model(cfg)
        key = jax.random.PRNGKey(1)
        params, _ = m.init_params(key=key)
        tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab)

        moe.MOE_IMPL = 'dense'
        ref, _ = jax.jit(lambda p, t: m.forward(p, t))(params, tokens)

        mesh = make_mesh((2, 2), ('data', 'model'))
        rules = make_rules(extra={'batch': ('pod', 'data', 'model')})
        set_rules(rules)
        moe.MOE_IMPL = 'auto'
        with set_mesh(mesh):
            out, _ = jax.jit(lambda p, t: m.forward(p, t))(params, tokens)
        set_rules(make_rules())
        err = float(jnp.max(jnp.abs(out - ref)))
        print('ERR', err)
        assert err < 5e-4, err
    """)
    assert "ERR" in out
