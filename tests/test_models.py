"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, output shapes + no NaNs; prefill+decode consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key):
    kwargs = {}
    if cfg.family == "encdec":
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        kwargs["frames"] = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model))
    elif cfg.family == "vlm":
        nv = 4
        tokens = jax.random.randint(key, (B, S - nv), 0, cfg.vocab)
        kwargs["patch_embeds"] = jax.random.normal(key, (B, nv, cfg.d_model))
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, axes = m.init_params(key=KEY)
    B, S = 2, 16
    tokens, kwargs = _inputs(cfg, B, S, KEY)
    logits, aux = m.forward(params, tokens, **kwargs)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    opt = adamw()
    step = jax.jit(make_train_step(m, opt, lambda s: 1e-3))
    B, S = 2, 16
    tokens, kwargs = _inputs(cfg, B, S, KEY)
    batch = {
        "tokens": tokens,
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        **kwargs,
    }
    params2, state, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(prompt) + decode(next) == forward(prompt+next) at the last pos."""
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    B, P = 2, 12
    tokens, kwargs = _inputs(cfg, B, P, KEY)
    tok_next = jax.random.randint(jax.random.fold_in(KEY, 1), (B, 1), 0, cfg.vocab)
    if cfg.family == "vlm":
        full = jnp.concatenate([tokens, tok_next], axis=1)
        logits_full, _ = m.forward(params, full, **kwargs)
        start_pos = kwargs["patch_embeds"].shape[1] + tokens.shape[1]
    else:
        full = jnp.concatenate([tokens, tok_next], axis=1)
        logits_full, _ = m.forward(params, full, **kwargs)
        start_pos = P
    lp, cache = m.prefill(params, tokens, cache_len=start_pos + 4, **kwargs)
    pos = jnp.full((B,), start_pos, jnp.int32)
    ld, _ = m.decode_step(params, tok_next, cache, pos)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32), np.asarray(logits_full[:, -2], np.float32),
        atol=5e-4, rtol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(logits_full[:, -1], np.float32),
        atol=5e-4, rtol=1e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims(arch):
    """The published dims are present and self-consistent."""
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    if cfg.family == "moe":
        assert cfg.param_count(active_only=True) < cfg.param_count()
    hd = cfg.resolved_head_dim()
    assert hd * cfg.n_heads >= cfg.d_model // 2  # sane head geometry


def test_rotating_window_decode_exact():
    """Sliding-window decode (rglru A-layers) matches full forward EVEN after
    the window wraps — guards the absolute-RoPE-phase fix."""
    cfg = smoke_config("recurrentgemma-2b")  # window = 8
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    B, S = 1, 20  # > 2x window
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits_full, _ = m.forward(params, tokens)

    # prefill 4, then decode 16 one at a time across the wrap boundary
    lp, cache = m.prefill(params, tokens[:, :4], cache_len=cfg.window)
    outs = [lp[:, 0]]
    for t in range(4, S):
        pos = jnp.full((B,), t, jnp.int32)
        ld, cache = m.decode_step(params, tokens[:, t:t + 1], cache, pos)
        outs.append(ld[:, 0])
    got = jnp.stack(outs, axis=1)            # predictions for positions 3..S-1
    want = logits_full[:, 3:]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=5e-4, rtol=1e-3,
    )
