"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, output shapes + no NaNs; prefill+decode consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key):
    kwargs = {}
    if cfg.family == "encdec":
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        kwargs["frames"] = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model))
    elif cfg.family == "vlm":
        nv = 4
        tokens = jax.random.randint(key, (B, S - nv), 0, cfg.vocab)
        kwargs["patch_embeds"] = jax.random.normal(key, (B, nv, cfg.d_model))
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, axes = m.init_params(key=KEY)
    B, S = 2, 16
    tokens, kwargs = _inputs(cfg, B, S, KEY)
    logits, aux = m.forward(params, tokens, **kwargs)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    opt = adamw()
    step = jax.jit(make_train_step(m, opt, lambda s: 1e-3))
    B, S = 2, 16
    tokens, kwargs = _inputs(cfg, B, S, KEY)
    batch = {
        "tokens": tokens,
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        **kwargs,
    }
    params2, state, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(prompt) + decode(next) == forward(prompt+next) at the last pos."""
    cfg = smoke_config(arch)
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    B, P = 2, 12
    tokens, kwargs = _inputs(cfg, B, P, KEY)
    tok_next = jax.random.randint(jax.random.fold_in(KEY, 1), (B, 1), 0, cfg.vocab)
    if cfg.family == "vlm":
        full = jnp.concatenate([tokens, tok_next], axis=1)
        logits_full, _ = m.forward(params, full, **kwargs)
        start_pos = kwargs["patch_embeds"].shape[1] + tokens.shape[1]
    else:
        full = jnp.concatenate([tokens, tok_next], axis=1)
        logits_full, _ = m.forward(params, full, **kwargs)
        start_pos = P
    lp, cache = m.prefill(params, tokens, cache_len=start_pos + 4, **kwargs)
    pos = jnp.full((B,), start_pos, jnp.int32)
    ld, _ = m.decode_step(params, tok_next, cache, pos)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32), np.asarray(logits_full[:, -2], np.float32),
        atol=5e-4, rtol=1e-3,
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(logits_full[:, -1], np.float32),
        atol=5e-4, rtol=1e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims(arch):
    """The published dims are present and self-consistent."""
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    if cfg.family == "moe":
        assert cfg.param_count(active_only=True) < cfg.param_count()
    hd = cfg.resolved_head_dim()
    assert hd * cfg.n_heads >= cfg.d_model // 2  # sane head geometry


@pytest.mark.parametrize("arch", ["whisper-medium", "qwen2-vl-7b",
                                  "recurrentgemma-2b"])
def test_int8_kv_cache_greedy_token_parity(arch):
    """kv_cache_dtype='int8' greedy decode tracks the native-dtype cache for
    the non-engine families (encdec / vlm / rglru); dense and moe are covered
    end-to-end by the ServeEngine int8 test.

    Both models consume the NATIVE model's greedy stream (teacher forcing), so
    one near-tie flip cannot compound.  Random-init logits have O(0.1) argmax
    margins while int8 KV adds O(1) logit noise, so token parity is asserted
    at every step whose native margin clears the measured noise — a layout or
    scale-plumbing bug produces O(logit-scale) errors and fails the closeness
    bound immediately."""
    cfg = smoke_config(arch)
    m = get_model(cfg)
    m8 = get_model(cfg.with_(kv_cache_dtype="int8"))
    params, _ = m.init_params(key=KEY)
    B, P, N = 2, 12, 6
    tokens, kwargs = _inputs(cfg, B, P, KEY)
    start_pos = P
    if cfg.family == "vlm":
        start_pos = kwargs["patch_embeds"].shape[1] + tokens.shape[1]
    cache_len = min(start_pos + N, cfg.window or start_pos + N)

    lp, cache = m.prefill(params, tokens, cache_len=cache_len, **kwargs)
    lp8, cache8 = m8.prefill(params, tokens, cache_len=cache_len, **kwargs)
    # prefill attention runs full-precision; only the cache is quantized
    np.testing.assert_allclose(np.asarray(lp8), np.asarray(lp), atol=1e-5)
    tok = jnp.argmax(lp[:, -1], axis=-1).astype(jnp.int32)[:, None]

    parity_checked = 0
    for t in range(N - 1):
        pos = jnp.full((B,), start_pos + t, jnp.int32)
        ld, cache = m.decode_step(params, tok, cache, pos)
        ld8, cache8 = m8.decode_step(params, tok, cache8, pos)
        scale = float(jnp.max(jnp.abs(ld)))
        top2 = jnp.sort(ld[:, -1], axis=-1)[:, -2:]
        margins = np.asarray(top2[:, 1] - top2[:, 0])
        want = np.asarray(jnp.argmax(ld[:, -1], axis=-1))
        got = np.asarray(jnp.argmax(ld8[:, -1], axis=-1))
        for b in range(B):
            rdiff = float(jnp.max(jnp.abs(ld8[b] - ld[b])))
            assert rdiff < 0.4 * scale, f"step {t} row {b}: {rdiff} vs {scale}"
            if margins[b] > 2.0 * rdiff:
                assert got[b] == want[b]
                parity_checked += 1
        tok = jnp.asarray(want, jnp.int32)[:, None]   # teacher-force native
    assert parity_checked >= N                         # the gate has teeth

    # the quantized KV really is smaller (int8 + 1/D-sized f32 scales);
    # rglru's R-state (conv window + lru h) is not a KV cache and stays f32
    if cfg.family == "rglru":
        cache8, cache = cache8["A"], cache["A"]
    nbytes = lambda c: sum(l.nbytes for l in jax.tree_util.tree_leaves(c))
    assert nbytes(cache8) < 0.5 * nbytes(cache)


def test_rotating_window_decode_exact():
    """Sliding-window decode (rglru A-layers) matches full forward EVEN after
    the window wraps — guards the absolute-RoPE-phase fix."""
    cfg = smoke_config("recurrentgemma-2b")  # window = 8
    m = get_model(cfg)
    params, _ = m.init_params(key=KEY)
    B, S = 1, 20  # > 2x window
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    logits_full, _ = m.forward(params, tokens)

    # prefill 4, then decode 16 one at a time across the wrap boundary
    lp, cache = m.prefill(params, tokens[:, :4], cache_len=cfg.window)
    outs = [lp[:, 0]]
    for t in range(4, S):
        pos = jnp.full((B,), t, jnp.int32)
        ld, cache = m.decode_step(params, tokens[:, t:t + 1], cache, pos)
        outs.append(ld[:, 0])
    got = jnp.stack(outs, axis=1)            # predictions for positions 3..S-1
    want = logits_full[:, 3:]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=5e-4, rtol=1e-3,
    )
