"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) CPU; only launch/dryrun.py forces 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def pytest_collection_modifyitems(config, items):
    """``@pytest.mark.tpu`` tests exercise the compiled (interpret=False)
    Pallas kernels; everywhere else they skip instead of erroring in the
    Mosaic lowering."""
    if _on_tpu():
        return
    skip = pytest.mark.skip(reason="requires a TPU (compiled Pallas kernels)")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip)
