"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) CPU; only launch/dryrun.py forces 512 devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
