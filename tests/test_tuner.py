"""Algorithm 1 (tuner) unit + property tests."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.api import FleetSpec
from repro.core import topology, tuner


def test_paper_fleet_margins():
    """Every Table-I network tunes to the paper's ~20% sync margin."""
    for net in ("mobilenetv2", "nasnet", "inceptionv3", "squeezenet"):
        f = topology.paper_fleet(24, net)
        r = tuner.tune(f, max_iters=128)
        th, tn = r.step_times["host"], r.step_times["newport"]
        margin = (th - tn) / tn
        assert 0.15 <= margin <= 0.30, (net, margin)


def test_nasnet_matches_table1_exactly():
    f = topology.paper_fleet(24, "nasnet")
    r = tuner.tune(f, max_iters=128)
    assert r.batches["host"] == 325  # paper Table I


def test_slowest_class_anchors():
    f = topology.paper_fleet(4, "mobilenetv2")
    r = tuner.tune(f)
    assert r.reference_class == "newport"
    # the slow class's batch never exceeds its DRAM cap
    assert r.batches["newport"] <= f.by_name("newport").max_batch


def test_respects_max_batch():
    fleet = (
        FleetSpec.custom("capped")
        .add("host", 1, 100.0, 8, 32, active_power=100.0)
        .add("csd", 2, 1.0, 4, 8, active_power=5.0)
        .build()
    )
    r = tuner.tune(fleet)
    assert r.batches["host"] <= 32
    assert r.batches["csd"] <= 8


@settings(max_examples=30, deadline=None)
@given(
    ratio=st.floats(min_value=1.5, max_value=200.0),
    E=st.floats(min_value=2.0, max_value=10.0),
    C=st.floats(min_value=2.0, max_value=50.0),
)
def test_margin_property(ratio, E, C):
    """For ANY throughput ratio and (C, E), the tuned fast class lands within
    the [0, 2/E] band around the target margin (discreteness tolerance),
    unless capped by max_batch."""
    fleet = (
        FleetSpec.custom("ratio")
        .add("fast", 1, ratio, 4, 10 ** 6, active_power=100.0)
        .add("slow", 1, 1.0, 4, 64, active_power=5.0)
        .build()
    )
    r = tuner.tune(fleet, C=C, E=E, max_iters=500)
    t_f, t_s = r.step_times["fast"], r.step_times["slow"]
    margin = (t_f - t_s) / t_s
    assert margin >= 1.0 / E - 1e-6, (margin, 1 / E)
    assert margin <= 2.5 / E + 0.05, (margin, 1 / E)


def test_drift_monitor_triggers_after_patience():
    m = tuner.DriftMonitor(margin=0.2, patience=3, alpha=1.0)
    assert not m.update({"a": 1.0, "b": 1.0})
    assert not m.update({"a": 1.0, "b": 2.0})   # breach 1
    assert not m.update({"a": 1.0, "b": 2.0})   # breach 2
    assert m.update({"a": 1.0, "b": 2.0})       # breach 3 -> retune
    # counter resets after firing
    assert not m.update({"a": 1.0, "b": 2.0})


def test_drift_monitor_recovers():
    m = tuner.DriftMonitor(margin=0.2, patience=2, alpha=1.0)
    m.update({"a": 1.0, "b": 2.0})
    assert not m.update({"a": 1.0, "b": 1.0})   # spread healed: counter resets
    assert not m.update({"a": 1.0, "b": 2.0})
